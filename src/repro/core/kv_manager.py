"""Hybrid paged-KV manager: the OS of the Utopia adaptation (paper §5.5/5.6).

Host-side authority over the physical KV-block pool.  Owns:

* the RestSeg (set-associative region): numpy TAR/SF mirrors + SRRIP rrpv,
* the FlexSeg (flexible region): free list + flat block table + refcounts
  (refcounts implement prefix sharing — the paper's "data sharing across
  processes", which is exactly what the restrictive mapping cannot do),
* allocation (page-fault-based: straight into the RestSeg),
* eviction (SRRIP within a set; evictee *migrates* to the FlexSeg — never
  dropped while flexible space remains, the paper's anti-swap argument),
* promotion (CostTracker: blocks with frequent+costly flexible walks move
  into the RestSeg),
* the swap analogue: when the whole pool is exhausted (or in
  ``restrictive_only`` mode, when a set conflicts with no flexible
  fallback), the block is evicted to "swap" = must be recomputed/host-
  fetched.  ``stats["swap_out"/"swap_in"]`` reproduce Fig. 9.

Device state (``device_state()``) is the packed TranslationState consumed by
``serve_step`` and the Pallas kernels.  Migration of KV *data* between pool
slots is performed on device by ``serve/engine.py`` (gather/scatter); the
manager emits the (src, dst) slot copy list for each step, the analogue of
the paper's DMA-driven page copy (§5.6, Fig. 16).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from .segments import HybridConfig
from .hashes import get_hash
from .policies import SRRIP, CostTracker, CostTrackerConfig

REST = 0
FLEX = 1
SWAP = 2


@dataclasses.dataclass
class BlockInfo:
    vpn: int
    seg: int           # REST / FLEX / SWAP
    slot: int          # global pool slot (-1 if swapped)
    refcount: int = 1  # >1 only in FlexSeg (sharing)
    writable: bool = True
    # per-vpn RSW-hit reuse counters (Fig. 26) live in
    # HybridKVManager.reuse_counts, not here: the vectorized stats
    # feedback writes them array-at-a-time


class PoolExhausted(RuntimeError):
    """Pool/slot exhaustion that cannot be served.

    ``diag`` carries structured occupancy diagnostics (the engine fills
    them in: pool size, mapped blocks, queued / live /
    finished-unreleased / preempted request counts) so operators see WHY
    admission failed, not just that it did.  The key=value pairs are also
    appended to the message for plain-string consumers."""

    def __init__(self, message: str = "pool exhausted", **diag):
        if diag:
            message = (message + " ["
                       + " ".join(f"{k}={v}"
                                  for k, v in sorted(diag.items())) + "]")
        super().__init__(message)
        self.diag = diag


class AllocLedger:
    """Exact dry-run of a sequence of ``allocate_block`` calls.

    Success of a batch of new-vpn allocations is order-independent under
    the manager's policy: each new vpn consumes an empty way of its own
    set when one exists and exactly one FlexSeg slot otherwise (an
    evict-migrate moves the SRRIP victim into the flex slot the new
    block would have taken — same net count; with ``alloc_evicts=False``
    the block lands in the flex slot directly).  A snapshot of per-set
    empty-way counts plus the flex free-list length therefore predicts
    allocate_block outcomes exactly, letting the serving engine decide
    preemption BEFORE mutating any table — a failed real allocation
    would leave a SWAP-state BlockInfo (and a dropped KV write) behind.

    ``reserve`` is all-or-nothing and updates the snapshot on success,
    so one ledger can gate a whole admission round incrementally.  In
    ``restrictive_only`` mode allocation never "fails" (a set conflict
    swaps the block, Fig. 9 semantics), so every reserve succeeds.

    A failed reserve is not necessarily final: the engine's capacity
    gate first reclaims unreferenced prefix-cache entries
    (``PrefixCache.evict_one`` frees a FlexSeg slot each) and retries
    with a FRESH ledger — dropping clean cache is the cheapest rung of
    the overload ladder, below preemption.
    """

    def __init__(self, mgr: "HybridKVManager"):
        self._mode = mgr.cfg.mode
        self._hash = mgr.hash
        self._num_sets = mgr.cfg.num_sets
        self._flex = len(mgr.flex_free)
        self._empty = ((mgr.tar == 0).sum(axis=1).astype(np.int64)
                       if self._mode != "flexible_only" else None)

    def reserve(self, vpns) -> bool:
        """Would allocating every (currently unmapped) vpn succeed?
        All-or-nothing: on True the capacity is deducted from the
        snapshot; on False the snapshot is unchanged."""
        if self._mode == "restrictive_only":
            return True
        flex = self._flex
        empty = None if self._empty is None else self._empty.copy()
        for vpn in vpns:
            if empty is not None:
                st = int(self._hash(int(vpn), self._num_sets))
                if empty[st] > 0:
                    empty[st] -= 1
                    continue
            if flex <= 0:
                return False
            flex -= 1
        self._flex = flex
        if empty is not None:
            self._empty = empty
        return True


class HybridKVManager:
    def __init__(self, cfg: HybridConfig):
        self.cfg = cfg
        self.hash = get_hash(cfg.hash_name)
        ns, assoc = cfg.num_sets, cfg.assoc
        # RestSeg mirrors
        self.tar = np.zeros((ns, assoc), np.int32)   # vpn+1, 0 empty
        self.sf = np.zeros(ns, np.int32)
        self.srrip = SRRIP(ns, assoc)
        # FlexSeg
        self.flex_free: List[int] = list(
            range(cfg.rest_slots, cfg.total_slots))
        self.flex_table = -np.ones(
            (cfg.max_seqs, cfg.max_blocks_per_seq), np.int32)
        # global views
        self.blocks: Dict[int, BlockInfo] = {}       # vpn -> info
        self.slot_refcount: Dict[int, int] = defaultdict(int)  # flex sharing
        self.slot_owner = -np.ones(cfg.total_slots, np.int64)  # slot -> vpn
        # slots owned (in addition to any live mappings) by the prefix
        # cache (core/prefix_cache.py): each holds one extra refcount so
        # cached content survives every sequence release.  Invariant:
        # slot_refcount[s] == flex-table occupancy + (s in cached_slots)
        self.cached_slots: set = set()
        self.seq_lengths: Dict[int, int] = {}        # seq_slot -> tokens
        self._free_seq_slots = list(range(cfg.max_seqs - 1, -1, -1))
        self._seq_ids: Dict[int, int] = {}           # user seq id -> seq slot
        self.tracker = CostTracker(
            cfg.vpn_space,
            CostTrackerConfig(freq_threshold=cfg.promote_freq_threshold,
                              cost_threshold=cfg.promote_cost_threshold))
        self.pending_copies: List[Tuple[int, int]] = []  # (src_slot, dst_slot)
        self.stats = defaultdict(int)
        self.reuse_histogram = defaultdict(int)      # reuse level at eviction
        # per-vpn RSW-hit counters (vectorized stats feedback writes here;
        # read at eviction for the Fig. 26 histogram)
        self.reuse_counts = np.zeros(cfg.vpn_space, np.int32)
        # dirty-entry tracking for delta device sync: set indices whose
        # TAR/SF row changed, and flat flex-table indices that changed,
        # since the last take_dirty() drain
        self._dirty_sets: set = set()
        self._dirty_flex: set = set()
        # sharded serving (DESIGN.md §sharded-serving): when the engine
        # partitions the translation structures over a mesh, per-shard
        # translation counters are attributed HERE — the same call site
        # that mutates the global counters — so cross-shard sums equal
        # the globals by construction, never by reconciliation
        self.partition = None
        self.shard_stats: List[Dict[str, int]] = []

    def __getstate__(self):
        """Pickle support (engine snapshot/restore): the resolved hash
        callable may not be picklable (vectorized/partial-backed
        registries) — drop it and re-derive from ``cfg.hash_name``."""
        state = dict(self.__dict__)
        state.pop("hash", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.hash = get_hash(self.cfg.hash_name)

    def set_partition(self, partition) -> None:
        """Attach a :class:`core.partition.Partition`: every subsequent
        ``record_device_stats`` also attributes rsw_hits (to the shard
        owning the vpn's SET) and flex_walks (to the shard owning the
        vpn's flex-table ROW) per shard."""
        self.partition = partition
        self.shard_stats = [defaultdict(int)
                            for _ in range(partition.n_shards)]

    # ----------------------------------------------------------- sequences
    def register_sequence(self, seq_id: int) -> int:
        if seq_id in self._seq_ids:
            return self._seq_ids[seq_id]
        if not self._free_seq_slots:
            raise PoolExhausted("out of sequence slots")
        s = self._free_seq_slots.pop()
        self._seq_ids[seq_id] = s
        self.seq_lengths[s] = 0
        return s

    def seq_slot(self, seq_id: int) -> int:
        return self._seq_ids[seq_id]

    def free_sequence(self, seq_id: int) -> None:
        s = self._seq_ids.pop(seq_id)
        for b in range(self.cfg.max_blocks_per_seq):
            vpn = s * self.cfg.max_blocks_per_seq + b
            if vpn in self.blocks:
                self._release(vpn)
        del self.seq_lengths[s]
        self._free_seq_slots.append(s)

    # ------------------------------------- whole-sequence preempt / resume
    def preempt(self, seq_id: int) -> List[Tuple[int, bool]]:
        """Swap a whole live sequence out to the host tier (ISSUE 6).

        Extends the per-block SWAP state to sequence granularity: every
        mapped block is released through the shared :meth:`_release` path
        (TAR/SF clears, flex-table unmaps, dirty marks), each counted as
        a ``swap_out`` with reason ``preempt``.  Shared-prefix blocks
        only drop THIS sequence's reference — the refcount decrement
        leaves the co-owner's physical slot untouched, so a sharer is
        never swapped out from under its co-owner; the preempted
        sequence gets a private copy of the prefix on resume.  The
        sequence slot is freed too, so resume may land on a different
        slot (the engine re-uploads the saved KV against the new
        mapping).

        Returns ``[(block_idx, writable)]`` for every block that was
        mapped (slot >= 0) at preemption — the caller must have gathered
        those slots' device data BEFORE calling this.  Blocks already in
        per-block SWAP state hold no pool data and are simply dropped.
        """
        if self.cfg.mode == "restrictive_only":
            raise ValueError(
                "preempt/resume needs a flexible segment to keep swapped "
                "sequences restorable (hybrid or flexible_only mode)")
        s = self.seq_slot(seq_id)
        saved: List[Tuple[int, bool]] = []
        for b in range(self.cfg.max_blocks_per_seq):
            info = self.blocks.get(self.cfg.vpn(s, b))
            if info is not None and info.slot >= 0:
                saved.append((b, info.writable))
        self.free_sequence(seq_id)
        self._count_swap_out("preempt", len(saved))
        self.stats["preempt_out"] += 1
        return saved

    def resume(self, seq_id: int, saved: List[Tuple[int, bool]]
               ) -> Dict[int, int]:
        """Re-admit a preempted sequence: a fresh sequence slot and fresh
        physical slots for every saved block, preserving per-block
        writability.  Returns ``{block_idx: new_slot}`` so the caller can
        scatter the host-tier KV back.  Capacity must be checked FIRST
        via :meth:`alloc_ledger` — this raises ``PoolExhausted`` if a
        saved block cannot be mapped, leaving the partial registration
        for the caller to tear down."""
        self.register_sequence(seq_id)
        out: Dict[int, int] = {}
        for b, writable in saved:
            info = self.allocate_block(seq_id, b, writable,
                                       count_fault=False)
            if info.slot < 0:
                raise PoolExhausted(
                    f"resume of sequence {seq_id} could not map block {b}")
            out[b] = info.slot
        self._count_swap_in("resume", len(saved))
        self.stats["preempt_in"] += 1
        return out

    def alloc_ledger(self) -> AllocLedger:
        """Snapshot an :class:`AllocLedger` for exact capacity dry-runs."""
        return AllocLedger(self)

    # ----------------- swap accounting: ONE mutation point per direction
    def _count_swap_out(self, reason: str, n: int = 1) -> None:
        """Sole mutation point for ``stats["swap_out"]`` (Fig. 9).  The
        per-reason breakdown (``swap_out_conflict`` — restrictive-only
        set conflict, ``swap_out_pool`` — flexible segment exhausted,
        ``swap_out_evict`` — RestSeg eviction with nowhere to migrate,
        ``swap_out_preempt`` — whole-sequence host-tier offload) always
        sums to the total, cross-checked by :meth:`check_invariants`, so
        the paper-figure counters and the overload/preemption counters
        cannot drift apart."""
        self.stats["swap_out"] += n
        self.stats[f"swap_out_{reason}"] += n

    def _count_swap_in(self, reason: str, n: int = 1) -> None:
        """Sole mutation point for ``stats["swap_in"]`` (reasons:
        ``fault`` — a per-block swap_in on access, ``resume`` — a
        host-tier sequence restore)."""
        self.stats["swap_in"] += n
        self.stats[f"swap_in_{reason}"] += n

    def free_block(self, seq_id: int, block_idx: int) -> bool:
        """Deallocate ONE block of a live sequence (speculative decode:
        a rejected draft tail crossed a block boundary, so the block it
        faulted in holds nothing committed).  RestSeg/FlexSeg bookkeeping
        — TAR/SF clears, flex-table unmap, refcounts, dirty marks for the
        delta sync — is the shared :meth:`_release` path.  Returns False
        when the block is not mapped (already freed / never allocated)."""
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        if vpn not in self.blocks:
            return False
        self._release(vpn)
        return True

    # ---------------------------------------------------------- allocation
    def allocate_block(self, seq_id: int, block_idx: int,
                       writable: bool = True, *,
                       count_fault: bool = True) -> BlockInfo:
        """Page-fault-based allocation (§5.5): RestSeg first.

        ``count_fault=False`` is the swap-in re-entry path: the block
        already faulted when it was first allocated, so bringing it back
        must count a ``swap_in`` (Fig. 9), not a fresh fault.
        """
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        if vpn in self.blocks:
            return self.blocks[vpn]
        if count_fault:
            self.stats["faults"] += 1
        if self.cfg.mode != "flexible_only":
            info = self._try_rest_alloc(vpn, writable)
            if info is not None:
                return info
            if self.cfg.mode == "restrictive_only":
                # no flexible fallback: the conflicting block goes to swap
                self._count_swap_out("conflict")
                info = BlockInfo(vpn=vpn, seg=SWAP, slot=-1, writable=writable)
                self.blocks[vpn] = info
                return info
        return self._flex_alloc(vpn, writable)

    def _try_rest_alloc(self, vpn: int, writable: bool,
                        allow_evict: Optional[bool] = None) -> Optional[BlockInfo]:
        st = self.hash(vpn, self.cfg.num_sets)
        row = self.tar[st]
        empty = np.nonzero(row == 0)[0]
        if empty.size:
            return self._rest_place(vpn, st, int(empty[0]), writable)
        if allow_evict is None:
            allow_evict = self.cfg.alloc_evicts
        if not allow_evict:
            return None
        if self.cfg.mode == "restrictive_only":
            victim_way = self.srrip.victim(st, row != 0)
            self._rest_evict(st, victim_way, to_swap=True)
            return self._rest_place(vpn, st, victim_way, writable)
        if not self.flex_free:
            return None  # nowhere to migrate the victim
        victim_way = self.srrip.victim(st, row != 0)
        self._rest_evict(st, victim_way, to_swap=False)
        return self._rest_place(vpn, st, victim_way, writable)

    def _rest_place(self, vpn: int, st: int, way: int, writable: bool) -> BlockInfo:
        self.tar[st, way] = vpn + 1
        self.sf[st] += 1
        self._dirty_sets.add(st)
        self.reuse_counts[vpn] = 0
        self.srrip.on_insert(st, way)
        slot = st * self.cfg.assoc + way
        info = BlockInfo(vpn=vpn, seg=REST, slot=slot, writable=writable)
        self.blocks[vpn] = info
        self.slot_owner[slot] = vpn
        self.stats["rest_allocs"] += 1
        return info

    def _flex_alloc(self, vpn: int, writable: bool) -> BlockInfo:
        if not self.flex_free:
            self._count_swap_out("pool")
            info = BlockInfo(vpn=vpn, seg=SWAP, slot=-1, writable=writable)
            self.blocks[vpn] = info
            return info
        slot = self.flex_free.pop()
        s, b = divmod(vpn, self.cfg.max_blocks_per_seq)
        self.flex_table[s, b] = slot
        self._dirty_flex.add(vpn)
        info = BlockInfo(vpn=vpn, seg=FLEX, slot=slot, writable=writable)
        self.blocks[vpn] = info
        self.slot_refcount[slot] = 1
        self.slot_owner[slot] = vpn
        self.stats["flex_allocs"] += 1
        return info

    # ------------------------------------------------------------ eviction
    def _rest_evict(self, st: int, way: int, to_swap: bool) -> None:
        """Evict a RestSeg way; migrate the victim to the FlexSeg (or swap)."""
        victim_vpn = int(self.tar[st, way]) - 1
        assert victim_vpn >= 0
        info = self.blocks[victim_vpn]
        self.reuse_histogram[min(int(self.reuse_counts[victim_vpn]), 64)] += 1
        old_slot = info.slot
        self.tar[st, way] = 0
        self.sf[st] -= 1
        self._dirty_sets.add(st)
        self.srrip.on_remove(st, way)
        self.slot_owner[old_slot] = -1
        self.stats["rest_evictions"] += 1
        if to_swap or not self.flex_free:
            self._count_swap_out("evict")
            info.seg, info.slot = SWAP, -1
            return
        new_slot = self.flex_free.pop()
        s, b = divmod(victim_vpn, self.cfg.max_blocks_per_seq)
        self.flex_table[s, b] = new_slot
        self._dirty_flex.add(victim_vpn)
        info.seg, info.slot = FLEX, new_slot
        self.reuse_counts[victim_vpn] = 0
        self.slot_refcount[new_slot] = 1
        self.slot_owner[new_slot] = victim_vpn
        self.pending_copies.append((old_slot, new_slot))
        self.stats["migrations_rest_to_flex"] += 1

    def _sync_shared_refcounts(self, slot: int) -> None:
        """Propagate ``slot_refcount[slot]`` to EVERY BlockInfo mapping the
        slot.  A shared slot has one BlockInfo per sharing vpn; updating
        only the src (the pre-fix behaviour) left prior sharers with a
        stale refcount when a third sequence joined.  The sharers are
        recovered from the flex table (one vectorized scan), not by
        sweeping the whole block registry."""
        rc = self.slot_refcount.get(slot, 0)
        for s, b in np.argwhere(self.flex_table == slot):
            info = self.blocks.get(
                int(s) * self.cfg.max_blocks_per_seq + int(b))
            if info is not None:
                info.refcount = rc

    def _release(self, vpn: int) -> None:
        info = self.blocks[vpn]
        if info.seg == FLEX:
            s, b = divmod(vpn, self.cfg.max_blocks_per_seq)
            self.flex_table[s, b] = -1
            self._dirty_flex.add(vpn)
            self.slot_refcount[info.slot] -= 1
            if self.slot_refcount[info.slot] > 0:
                # another sequence still references the shared slot
                del self.blocks[vpn]
                self._sync_shared_refcounts(info.slot)
                return
            del self.slot_refcount[info.slot]
            if self.slot_owner[info.slot] == vpn:
                self.slot_owner[info.slot] = -1
            self.flex_free.append(info.slot)
        elif info.seg == REST:
            st = self.hash(vpn, self.cfg.num_sets)
            way = info.slot - st * self.cfg.assoc
            self.tar[st, way] = 0
            self.sf[st] -= 1
            self._dirty_sets.add(st)
            self.srrip.on_remove(st, way)
            self.slot_owner[info.slot] = -1
        del self.blocks[vpn]

    # ----------------------------------------------------------- promotion
    def record_device_stats(self, vpns: np.ndarray, in_rest: np.ndarray,
                            accesses: np.ndarray) -> None:
        """Feed back per-step device translation stats (paper: PTE counters).

        Fully vectorized: the RSW-hit way recovery is a batched TAR tag
        match (tar[h(vpn)] == vpn+1 iff the vpn is REST-resident), SRRIP
        promotion and reuse counting are one fancy-indexed write each —
        no per-vpn Python loop on the per-step path.
        """
        vpns = np.asarray(vpns).ravel().astype(np.int64)
        in_rest = np.asarray(in_rest).ravel().astype(bool)
        accesses = np.asarray(accesses).ravel()
        hits = vpns[in_rest]
        if hits.size:
            sts = np.asarray(self.hash(hits.astype(np.int32),
                                       self.cfg.num_sets))
            eq = self.tar[sts] == (hits[:, None] + 1)
            ok = eq.any(axis=1)                  # still REST-resident
            ways = eq.argmax(axis=1)
            self.srrip.on_hit_batch(sts[ok], ways[ok])
            np.add.at(self.reuse_counts, hits[ok], 1)
        self.stats["rsw_hits"] += int(in_rest.sum())
        miss = ~in_rest
        self.stats["flex_walks"] += int(miss.sum())
        if self.partition is not None:
            part = self.partition
            hit_sets = np.asarray(self.hash(vpns[in_rest].astype(np.int32),
                                            self.cfg.num_sets))
            hit_owner = part.shard_of_set(hit_sets)
            walk_owner = part.shard_of_vpn(vpns[miss])
            for s in range(part.n_shards):
                self.shard_stats[s]["rsw_hits"] += int((hit_owner == s).sum())
                self.shard_stats[s]["flex_walks"] += int(
                    (walk_owner == s).sum())
        if miss.any():
            self.tracker.record_walk(vpns[miss], accesses[miss])

    def run_promotions(self) -> int:
        """Migrate costly-to-translate FlexSeg blocks into the RestSeg."""
        if self.cfg.mode != "hybrid":
            return 0
        n = 0
        for vpn in self.tracker.take_promotions():
            info = self.blocks.get(int(vpn))
            if (info is None or info.seg != FLEX
                    or self.slot_refcount.get(info.slot, 1) > 1):
                continue  # shared blocks must stay flexible (paper §5.1)
            old_slot = info.slot
            placed = self._try_rest_alloc(int(vpn), info.writable,
                                          allow_evict=True)
            if placed is None:
                continue
            # _try_rest_alloc re-registered vpn; fix bookkeeping of old slot
            s, b = divmod(int(vpn), self.cfg.max_blocks_per_seq)
            self.flex_table[s, b] = -1
            self._dirty_flex.add(int(vpn))
            self.slot_refcount.pop(old_slot, None)
            self.flex_free.append(old_slot)
            if self.slot_owner[old_slot] == vpn:
                self.slot_owner[old_slot] = -1
            self.pending_copies.append((old_slot, placed.slot))
            self.stats["migrations_flex_to_rest"] += 1
            n += 1
        return n

    # ------------------------------------------------------------- sharing
    def share_prefix(self, src_seq_id: int, dst_seq_id: int,
                     n_blocks: int) -> int:
        """Map dst's first n_blocks onto src's physical slots (copy-on-share
        migration out of the RestSeg first: restrictive slots are tag-bound
        to a single vpn, the paper's sharing limitation)."""
        ss = self.seq_slot(src_seq_id)
        ds = self.seq_slot(dst_seq_id)
        shared = 0
        for b in range(n_blocks):
            src_vpn = self.cfg.vpn(ss, b)
            info = self.blocks.get(src_vpn)
            if info is None or info.seg == SWAP:
                continue
            if info.seg == REST:
                info = self._migrate_rest_to_flex(src_vpn)
                if info is None:
                    continue
            dst_vpn = self.cfg.vpn(ds, b)
            if dst_vpn in self.blocks:
                self._release(dst_vpn)
            self.slot_refcount[info.slot] += 1
            rc = self.slot_refcount[info.slot]
            self.flex_table[ds, b] = info.slot
            self._dirty_flex.add(dst_vpn)
            self.blocks[dst_vpn] = BlockInfo(
                vpn=dst_vpn, seg=FLEX, slot=info.slot,
                refcount=rc, writable=False)
            # every sharer's BlockInfo must see the new refcount, not just
            # the src: a third joiner previously left the second with a
            # stale count
            self._sync_shared_refcounts(info.slot)
            info.writable = False  # copy-on-write semantics after sharing
            self.stats["shared_blocks"] += 1
            shared += 1
        return shared

    def _migrate_rest_to_flex(self, vpn: int) -> Optional[BlockInfo]:
        if not self.flex_free:
            return None
        info = self.blocks[vpn]
        st = self.hash(vpn, self.cfg.num_sets)
        way = info.slot - st * self.cfg.assoc
        old_slot = info.slot
        self.tar[st, way] = 0
        self.sf[st] -= 1
        self._dirty_sets.add(st)
        self.srrip.on_remove(st, way)
        self.slot_owner[old_slot] = -1
        new_slot = self.flex_free.pop()
        s, b = divmod(vpn, self.cfg.max_blocks_per_seq)
        self.flex_table[s, b] = new_slot
        self._dirty_flex.add(vpn)
        info.seg, info.slot = FLEX, new_slot
        self.slot_refcount[new_slot] = 1
        self.slot_owner[new_slot] = vpn
        self.pending_copies.append((old_slot, new_slot))
        self.stats["migrations_rest_to_flex"] += 1
        return info

    # ------------------------------------- prefix-cache slot ownership
    def cache_pin_block(self, seq_id: int, block_idx: int) -> Optional[int]:
        """Give the prefix cache a reference on a live block's slot.

        Same copy-on-share rules as :meth:`share_prefix`: the block must
        live in the FlexSeg (a restrictive slot is tag-bound to one vpn),
        so a REST-resident block migrates first — and the pin fails
        (``None``) when the block is swapped, unmapped, already cached,
        or no FlexSeg slot is free to migrate into.  On success the
        slot's refcount grows by one CACHE reference (not tied to any
        sequence), the block becomes read-only, and the slot is recorded
        in ``cached_slots`` for the invariant cross-check.
        """
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        info = self.blocks.get(vpn)
        if info is None or info.seg == SWAP:
            return None
        if info.seg == REST:
            info = self._migrate_rest_to_flex(vpn)
            if info is None:
                return None
        if info.slot in self.cached_slots:
            return None  # one cache entry per physical slot
        self.slot_refcount[info.slot] += 1
        self.cached_slots.add(info.slot)
        info.writable = False  # cached content is immutable
        self._sync_shared_refcounts(info.slot)
        self.stats["cache_pinned_blocks"] += 1
        return info.slot

    def cache_unpin_slot(self, slot: int) -> None:
        """Drop the cache's reference on a slot (entry evicted).  When
        that was the last reference the slot returns to the free list;
        otherwise live attachers keep it (their refcounts re-synced)."""
        assert slot in self.cached_slots, f"slot {slot} not cache-owned"
        self.cached_slots.discard(slot)
        self.slot_refcount[slot] -= 1
        if self.slot_refcount[slot] <= 0:
            del self.slot_refcount[slot]
            self.slot_owner[slot] = -1
            self.flex_free.append(slot)
        else:
            self._sync_shared_refcounts(slot)

    def attach_cached_block(self, seq_id: int, block_idx: int,
                            slot: int) -> BlockInfo:
        """Map a sequence block onto a cache-owned slot, read-only.

        The cache-hit analogue of the dst half of :meth:`share_prefix`:
        the new vpn joins the slot's sharers (refcount + flex-table
        entry + dirty mark for the delta sync) without copying KV —
        the whole point of content-addressed dedup.
        """
        assert slot in self.cached_slots, f"slot {slot} not cache-owned"
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        if vpn in self.blocks:
            self._release(vpn)
        self.slot_refcount[slot] += 1
        self.flex_table[s, block_idx] = slot
        self._dirty_flex.add(vpn)
        info = BlockInfo(vpn=vpn, seg=FLEX, slot=slot,
                         refcount=self.slot_refcount[slot], writable=False)
        self.blocks[vpn] = info
        self._sync_shared_refcounts(slot)
        self.stats["shared_blocks"] += 1
        self.stats["cache_attached_blocks"] += 1
        return info

    # ----------------------------------------------------------- swap path
    def swap_in(self, seq_id: int, block_idx: int) -> BlockInfo:
        """Bring a swapped block back (counts a swap access, Fig. 9)."""
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        info = self.blocks.get(vpn)
        if info is None or info.seg != SWAP:
            raise ValueError(f"vpn {vpn} not in swap")
        self._count_swap_in("fault")
        del self.blocks[vpn]
        return self.allocate_block(seq_id, block_idx, info.writable,
                                   count_fault=False)

    # ------------------------------------------------------------- lookups
    def lookup(self, seq_id: int, block_idx: int) -> Tuple[int, int]:
        """Host-side translate; returns (slot, seg)."""
        s = self.seq_slot(seq_id)
        vpn = self.cfg.vpn(s, block_idx)
        info = self.blocks.get(vpn)
        if info is None:
            return -1, -1
        return info.slot, info.seg

    def take_pending_copies(self) -> List[Tuple[int, int]]:
        out, self.pending_copies = self.pending_copies, []
        self.stats["copies_issued"] += len(out)
        return out

    def take_dirty(self) -> Tuple[np.ndarray, np.ndarray]:
        """Drain the dirty-entry sets accumulated since the last call.

        Returns (set_indices, flat_flex_indices): the TAR/SF rows and the
        flat flex-table entries a delta device sync must re-upload.
        """
        sets = np.array(sorted(self._dirty_sets), np.int64)
        flex = np.array(sorted(self._dirty_flex), np.int64)
        self._dirty_sets.clear()
        self._dirty_flex.clear()
        return sets, flex

    # --------------------------------------------------------- device view
    def device_state(self):
        """Pack host mirrors into the device TranslationState."""
        import jax.numpy as jnp
        from .tar_sf import RestSegState
        from .flex_table import FlexTable
        from .translate import TranslationState
        return TranslationState(
            rest=RestSegState(tar=jnp.asarray(self.tar),
                              sf=jnp.asarray(self.sf),
                              meta=jnp.zeros_like(jnp.asarray(self.tar))),
            flex=FlexTable(table=jnp.asarray(self.flex_table)),
            rest_base=jnp.zeros((), jnp.int32),
            max_blocks_per_seq=self.cfg.max_blocks_per_seq,
            hash_name=self.cfg.hash_name,
        )

    def slot_owner_array(self) -> np.ndarray:
        """slot -> vpn inverse map (slot-major attention layout)."""
        return self.slot_owner.copy()

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Debug/property-test oracle: structural consistency."""
        assert (self.sf == (self.tar != 0).sum(axis=1)).all(), "SF != TAR occupancy"
        for vpn, info in self.blocks.items():
            if info.seg == REST:
                st = self.hash(vpn, self.cfg.num_sets)
                way = info.slot - st * self.cfg.assoc
                assert 0 <= way < self.cfg.assoc, f"slot {info.slot} not in set {st}"
                assert self.tar[st, way] == vpn + 1, "TAR tag mismatch"
                assert self.slot_owner[info.slot] == vpn
            elif info.seg == FLEX:
                s, b = divmod(vpn, self.cfg.max_blocks_per_seq)
                assert self.flex_table[s, b] == info.slot, "flex table mismatch"
                assert info.slot >= self.cfg.rest_slots
                assert info.refcount == self.slot_refcount.get(info.slot), \
                    (f"BlockInfo.refcount {info.refcount} stale for slot "
                     f"{info.slot} (slot_refcount="
                     f"{self.slot_refcount.get(info.slot)})")
        mapped_flex = set(int(x) for x in self.flex_table.ravel() if x >= 0)
        free_flex = set(self.flex_free)
        assert not (mapped_flex & free_flex), "slot both mapped and free"
        # slot_refcount must equal flex-table occupancy plus the prefix
        # cache's own reference exactly: each refcount is the number of
        # (seq, block) flex entries mapping the slot, +1 iff the cache
        # pinned it (the PR-8 cache-ownership cross-check — a rogue
        # release of a cached slot, or a cache pin that leaked, breaks
        # this equality immediately)
        occ: Dict[int, int] = defaultdict(int)
        for x in self.flex_table.ravel():
            if x >= 0:
                occ[int(x)] += 1
        want = dict(occ)
        for slot in self.cached_slots:
            want[slot] = want.get(slot, 0) + 1
        rc = {s: c for s, c in self.slot_refcount.items() if c != 0}
        assert rc == want, \
            (f"slot_refcount {rc} != flex occupancy + cache refs {want} "
             f"(cached_slots={sorted(self.cached_slots)})")
        for slot in self.cached_slots:
            assert slot >= self.cfg.rest_slots, \
                f"cached slot {slot} is in the RestSeg (must be FlexSeg)"
            assert slot not in free_flex, \
                f"cached slot {slot} is also on the free list"
            for s, b in np.argwhere(self.flex_table == slot):
                info = self.blocks.get(
                    int(s) * self.cfg.max_blocks_per_seq + int(b))
                assert info is None or not info.writable, \
                    f"cached slot {slot} has a WRITABLE live mapping"
        # every mapped block must belong to a REGISTERED sequence: a
        # preempted/freed sequence leaving blocks behind is a pool leak
        for vpn in self.blocks:
            assert vpn // self.cfg.max_blocks_per_seq in self.seq_lengths, \
                f"block vpn {vpn} belongs to an unregistered sequence"
        # swap accounting: the totals are mutated ONLY through
        # _count_swap_out/_count_swap_in, so they must equal their
        # per-reason breakdowns exactly (Fig. 9 vs preemption counters)
        for d in ("swap_out", "swap_in"):
            parts = sum(v for k, v in self.stats.items()
                        if k.startswith(d + "_"))
            assert self.stats.get(d, 0) == parts, \
                (f"stats[{d!r}]={self.stats.get(d, 0)} != sum of "
                 f"per-reason counters {parts}")
        # sharded serving: per-shard attribution must sum EXACTLY to the
        # global counters (same mutation site, so drift is a bug)
        if self.partition is not None:
            for key in ("rsw_hits", "flex_walks"):
                total = sum(s.get(key, 0) for s in self.shard_stats)
                assert total == self.stats.get(key, 0), \
                    (f"per-shard {key} sum {total} != global "
                     f"{self.stats.get(key, 0)}")
