"""Set-index hash functions for the RestSeg (paper §8.3.8, Fig. 30).

All functions are polymorphic over numpy and jax.numpy int32 arrays (and
Python ints): only ``%``, ``^``, ``>>``, ``*``, ``+`` are used so the same
code drives the host-side allocator, the pure-JAX oracle and the Pallas
kernels.  Inputs are virtual block numbers (vpns); output is a set index in
``[0, n_sets)``.
"""
from __future__ import annotations

_MIX = 73244475      # int32-safe mixing prime (0x045D9F3B)


def mix32(x):
    """int32 wrap-around mixer, identical semantics on python ints, numpy
    int32 arrays and jnp int32 arrays (callers must pass int32-typed arrays
    or masked python ints; jax runs with x64 disabled)."""
    import numpy as _np
    with _np.errstate(over="ignore"):   # int32 wrap is intended
        x = (x * _MIX) & 0x7FFFFFFF
        x = x ^ (x >> 15)
        x = (x * _MIX) & 0x7FFFFFFF
        return x ^ (x >> 13)


def modulo_hash(vpn, n_sets: int):
    """Paper's chosen function: best performance/complexity trade-off."""
    return vpn % n_sets


def xor_fold_hash(vpn, n_sets: int):
    """XOR-based hashing [Cho et al.]: fold upper bits into the index."""
    set_bits = max(1, (n_sets - 1).bit_length())
    folded = vpn ^ (vpn >> set_bits) ^ (vpn >> (2 * set_bits))
    return folded % n_sets


def prime_displacement_hash(vpn, n_sets: int):
    """Prime-displacement [Kharbutli et al.]: idx = (tag * p + idx0) mod sets."""
    set_bits = max(1, (n_sets - 1).bit_length())
    tag = vpn >> set_bits
    idx0 = vpn % n_sets
    return (tag * 17 + idx0) % n_sets


def mersenne_hash(vpn, n_sets: int):
    """Mersenne-modulo [Yang & Yang]: reduce mod (2^k - 1) first."""
    k = max(2, (n_sets - 1).bit_length())
    m = (1 << k) - 1
    x = vpn
    # two folding rounds bring any 32-bit value below 2^(k+1)
    x = (x & m) + (x >> k)
    x = (x & m) + (x >> k)
    return x % n_sets


def multiplicative_hash(vpn, n_sets: int):
    """Beyond-paper: multiplicative scramble (cheap on the TPU scalar unit)."""
    return mix32(vpn) % n_sets


HASHES = {
    "modulo": modulo_hash,
    "xor_fold": xor_fold_hash,
    "prime_displacement": prime_displacement_hash,
    "mersenne": mersenne_hash,
    "multiplicative": multiplicative_hash,
}


def get_hash(name: str):
    try:
        return HASHES[name]
    except KeyError:
        raise KeyError(f"unknown hash {name!r}; options: {sorted(HASHES)}")
