import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).  Placeholder CPU devices stand in
# for the production TPU mesh: 16x16 = one pod, 2x16x16 = two pods.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step /
prefill_step / serve_step) with production shardings, lowers it against
ShapeDtypeStruct inputs (no allocation), compiles it, and records

  * compiled.memory_analysis()  — proves the cell fits per-device HBM,
  * compiled.cost_analysis()    — FLOPs/bytes for the roofline,
  * parsed collective traffic   — bytes per device by collective kind,

into benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both] [-j N]
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
RESULTS_DIR = os.path.join(ROOT, "benchmarks", "results", "dryrun")


def _cell_list():
    from repro.configs import ARCHS, SHAPES, cell_applicable
    cells = []
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            ok, why = cell_applicable(ARCHS[arch], shape)
            cells.append((arch, shape.name, ok, why))
    return cells


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               perf_variant: str = "baseline"):
    """Returns (lowered, meta) for one cell."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, shape_cell, cell_applicable
    from repro.models import FwdOptions, model_dims, init_params
    from repro.dist.sharding import ShardingRules, make_pins, param_shardings
    from repro.train import (TrainConfig, make_train_step, abstract_state,
                             state_shardings)
    from repro.serve.decode import (make_decode_spec, make_serve_step,
                                    abstract_decode_state,
                                    decode_state_shardings)
    from repro.serve.prefill import make_prefill_step
    from repro.launch.mesh import make_production_mesh, data_axes_for
    from repro.launch import perf_variants

    cfg = get_config(arch)
    shape = shape_cell(shape_name)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise SystemExit(f"SKIP: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    da = data_axes_for(mesh)
    tp = mesh.shape["model"]
    G = 1
    for a in da:
        G *= mesh.shape[a]
    dims = model_dims(cfg, tp=tp)
    rules = ShardingRules(data_axes=da, zero_params=cfg.zero_shard_params)
    cfg, rules, fwd_over = perf_variants.apply(perf_variant, cfg, rules,
                                               shape, multi_pod)
    pins = make_pins(mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.bfloat16

    params_abs = jax.eval_shape(
        lambda k: init_params(k, cfg, dims, dtype=dtype),
        jax.random.PRNGKey(0))
    params_sh = param_shardings(params_abs, rules, mesh)
    sd = jax.ShapeDtypeStruct

    def batch_abs_sh(with_labels: bool):
        b = {"tokens": sd((B, S), jnp.int32)}
        s = {"tokens": NamedSharding(mesh, P(da, None))}
        if with_labels:
            b["labels"] = sd((B, S), jnp.int32)
            s["labels"] = NamedSharding(mesh, P(da, None))
        if cfg.frontend != "none":
            b["frontend"] = sd((B, cfg.frontend_tokens, cfg.d_model), dtype)
            s["frontend"] = NamedSharding(mesh, P(da, None, None))
        return b, s

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind, "tp": tp, "data_shards": G,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "perf_variant": perf_variant}

    if shape.kind == "train":
        use_megatron = fwd_over.pop("_megatron", False)
        fwd = FwdOptions(attn_impl="flash_jax", dtype=dtype, remat=cfg.remat,
                         q_chunk=1024, kv_chunk=1024, moe_groups=G,
                         **fwd_over)
        tc = TrainConfig(dtype=dtype, grad_compression=multi_pod,
                         microbatches=cfg.train_microbatches,
                         accum_dtype=(jnp.bfloat16
                                      if cfg.optimizer == "adafactor"
                                      else jnp.float32))
        state_abs = abstract_state(cfg, dims, tc, param_dtype=dtype)
        loss_override = None
        if use_megatron:
            if cfg.family != "dense":
                raise SystemExit("SKIP: megatron variant is dense-only")
            from repro.dist.megatron import (make_megatron_forward,
                                             megatron_param_shardings)
            mfwd = make_megatron_forward(
                cfg, dims, mesh, da, attn_impl="flash_jax",
                triangular=fwd.triangular_schedule, remat=cfg.remat)

            def loss_override(params, batch):
                logits, aux, _ = mfwd(params, batch)
                labels = batch["labels"]
                mask = (labels >= 0).astype(jnp.float32)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                ll = jnp.take_along_axis(
                    logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
                ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
                return ce, {"ce": ce, "loss": ce}

            p_sh = megatron_param_shardings(state_abs["params"], mesh, rules)
            state_sh = state_shardings(state_abs, mesh, rules)
            state_sh["params"] = p_sh
            if "opt" in state_sh and "m" in state_sh["opt"]:
                state_sh["opt"] = {"m": p_sh, "v": p_sh}
        else:
            state_sh = state_shardings(state_abs, mesh, rules)
        step = make_train_step(cfg, dims, tc, fwd, mesh, rules,
                               loss_override=loss_override)
        batch_abs, batch_sh = batch_abs_sh(True)
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)
                              ).lower(state_abs, batch_abs)
        return lowered, meta

    # ---- inference cells ----
    mode = "striped" if shape_name == "long_500k" else "batch"
    seq_eff = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    spec = make_decode_spec(cfg, seq_eff, B, G, mode=mode, data_axes=da)
    dstate_abs = abstract_decode_state(cfg, dims, spec, B, G, dtype)
    dstate_sh = decode_state_shardings(dstate_abs, mesh, spec)

    if shape.kind == "prefill":
        fwd = FwdOptions(attn_impl="flash_jax", dtype=dtype, remat=False,
                         q_chunk=1024, kv_chunk=1024, moe_groups=G,
                         **fwd_over)
        step = make_prefill_step(cfg, dims, spec, mesh, pins, fwd)
        batch_abs, batch_sh = batch_abs_sh(False)
        nblk = seq_eff // spec.block_size
        slots_abs = sd((B, nblk), jnp.int32)
        slots_sh = NamedSharding(mesh, P(da, None))
        row_abs = sd((B,), jnp.int32)       # slot_ids / ctx / last_pos
        row_sh = NamedSharding(mesh, P(da))
        with mesh:
            lowered = jax.jit(step, in_shardings=(
                params_sh, dstate_sh, batch_sh, slots_sh,
                row_sh, row_sh, row_sh),
                donate_argnums=(1,)
                ).lower(params_abs, dstate_abs, batch_abs, slots_abs,
                        row_abs, row_abs, row_abs)
        return lowered, meta

    if shape.kind == "decode":
        if fwd_over.pop("_kv_int8", False):
            # int8 KV pool (vLLM-style quantized cache): halves the decode
            # memory term; dequant scale folded for structural analysis
            for k in ("k_pool", "v_pool"):
                if k in dstate_abs:
                    dstate_abs[k] = jax.ShapeDtypeStruct(
                        dstate_abs[k].shape, jnp.int8)
        step = make_serve_step(cfg, dims, spec, mesh, pins, dtype)
        tokens_abs = sd((B,), jnp.int32)
        tokens_sh = NamedSharding(mesh, P(da) if mode == "batch" and
                                  B % G == 0 else P())
        with mesh:
            lowered = jax.jit(step, in_shardings=(
                params_sh, dstate_sh, tokens_sh),
                donate_argnums=(1,)
                ).lower(params_abs, dstate_abs, tokens_abs)
        return lowered, meta

    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             perf_variant: str = "baseline", save_hlo: bool = False) -> dict:
    sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    import hlo_analysis

    # monotonic clock for durations: time.time() can jump under NTP
    t0 = time.perf_counter()
    lowered, meta = build_cell(arch, shape_name, multi_pod, perf_variant)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    ca = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = hlo_analysis.analyze_collectives(hlo)
    costs = hlo_analysis.loop_corrected_costs(compiled, hlo)
    weighted = hlo_analysis.analyze_costs(hlo)

    n_dev = 512 if multi_pod else 512  # host device count; mesh uses subset
    result = dict(meta)
    result.update({
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device_raw": float(ca.get("flops", 0.0)),
        "bytes_per_device_raw": float(ca.get("bytes accessed", 0.0)),
        "flops_per_device": weighted["flops_weighted"],
        "bytes_per_device": weighted["bytes_weighted"],
        "top_computations": weighted["top_computations"],
        "loop_trip_counts": costs["loop_trip_counts"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": coll,
        "hlo_chars": len(hlo),
    })
    if save_hlo:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        import gzip
        tag = f"{arch}__{shape_name}__{result['mesh']}__{perf_variant}"
        with gzip.open(os.path.join(RESULTS_DIR, tag + ".hlo.gz"),
                       "wt") as f:
            f.write(hlo)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--perf-variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("-j", type=int, default=2, help="parallel cells (--all)")
    args = ap.parse_args()

    if args.list:
        for arch, shape, ok, why in _cell_list():
            print(f"{'RUN ' if ok else 'SKIP'} {arch:26s} {shape:12s} {why}")
        return

    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.all:
        meshes = {"pod": [False], "multipod": [True],
                  "both": [False, True]}[args.mesh]
        jobs = []
        for arch, shape, ok, why in _cell_list():
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                out = os.path.join(RESULTS_DIR, tag + ".json")
                if not ok:
                    with open(out, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": "2x16x16" if mp else "16x16",
                                   "ok": True, "skipped": True,
                                   "skip_reason": why}, f, indent=1)
                    print(f"SKIP {tag}: {why}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", "multipod" if mp else "pod"]
                jobs.append((tag, cmd, out))
        running = []
        failed = []
        while jobs or running:
            while jobs and len(running) < args.j:
                tag, cmd, out = jobs.pop(0)
                env = dict(os.environ)
                env["PYTHONPATH"] = os.path.join(ROOT, "src")
                p = subprocess.Popen(cmd, env=env,
                                     stdout=subprocess.PIPE,
                                     stderr=subprocess.STDOUT, text=True)
                running.append((tag, p, out, time.perf_counter()))
            time.sleep(1.0)
            for item in list(running):
                tag, p, out, t0 = item
                if p.poll() is None:
                    continue
                running.remove(item)
                dt = time.perf_counter() - t0
                if p.returncode == 0 and os.path.exists(out):
                    print(f"PASS {tag} ({dt:.0f}s)")
                else:
                    failed.append(tag)
                    log = p.stdout.read() if p.stdout else ""
                    with open(out.replace(".json", ".log"), "w") as f:
                        f.write(log)
                    print(f"FAIL {tag} ({dt:.0f}s) — see "
                          f"{out.replace('.json', '.log')}")
        print(f"\n{'ALL CELLS PASS' if not failed else 'FAILED: ' + str(failed)}")
        sys.exit(1 if failed else 0)

    # single cell
    assert args.arch and args.shape, "--arch and --shape required"
    for mp in ({"pod": [False], "multipod": [True],
                "both": [False, True]}[args.mesh]):
        result = run_cell(args.arch, args.shape, mp,
                          perf_variant=args.perf_variant,
                          save_hlo=args.save_hlo)
        tag = (f"{args.arch}__{args.shape}__{result['mesh']}"
               + ("" if args.perf_variant == "baseline"
                  else f"__{args.perf_variant}"))
        out = os.path.join(RESULTS_DIR, tag + ".json")
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        mb = result["memory"]
        print(f"{tag}: lower {result['lower_s']}s compile "
              f"{result['compile_s']}s | args "
              f"{mb['argument_bytes']/2**30:.2f} GiB temp "
              f"{mb['temp_bytes']/2**30:.2f} GiB | flops/dev "
              f"{result['flops_per_device']:.3e} | coll "
              f"{result['collectives']['collective_bytes_per_device']/2**30:.3f} GiB")


if __name__ == "__main__":
    main()
