"""Production training launcher.

Builds the sharded train step for an --arch on the local (or production)
mesh, restores the latest checkpoint if present, and runs the resilient
loop with async checkpointing and deterministic data replay.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \\
        --steps 100 --data-mesh 1 --model-mesh 1 [--reduced]

On a real TPU pod slice the same entry point runs under
``JAX_PROCESS_COUNT``-style multi-host initialization; the mesh axes map
onto the slice topology exactly as in the dry-run.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import FwdOptions, model_dims
from repro.train import (TrainConfig, make_train_step, init_state,
                         state_shardings)
from repro.dist.sharding import ShardingRules
from repro.data import DataConfig, SyntheticLM
from repro.ckpt import CheckpointManager
from repro.runtime import ResilientLoop
from repro.launch.mesh import make_local_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU friendly)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    use_mesh = args.data_mesh * args.model_mesh > 1
    mesh = make_local_mesh(args.data_mesh, args.model_mesh) if use_mesh \
        else None
    rules = ShardingRules(data_axes=("data",),
                          zero_params=cfg.zero_shard_params)
    dims = model_dims(cfg, tp=args.model_mesh)
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                     total_steps=args.steps, dtype=jnp.float32,
                     microbatches=cfg.train_microbatches)
    state = init_state(jax.random.PRNGKey(0), cfg, dims, tc)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh="
          f"{args.data_mesh}x{args.model_mesh}")

    step = make_train_step(cfg, dims, tc, FwdOptions(
        attn_impl="dense" if args.reduced else "flash_jax",
        dtype=jnp.float32, remat=cfg.remat), mesh, rules)
    if mesh is not None:
        sh = state_shardings(jax.eval_shape(lambda: state), mesh, rules)
        state = jax.device_put(state, sh)
        step_fn = jax.jit(step, in_shardings=(sh, None),
                          out_shardings=(sh, None))
    else:
        step_fn = jax.jit(step)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=0,
        frontend_tokens=cfg.frontend_tokens if cfg.frontend != "none" else 0,
        d_model=cfg.d_model))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=3)
    latest = ckpt.latest_step()
    if latest is not None:
        restored, s = ckpt.restore(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        state = jax.tree.map(jnp.asarray, restored)
        print(f"restored checkpoint at step {s}")
    loop = ResilientLoop(ckpt, data, step_fn, ckpt_every=50)
    report = loop.run(state, total_steps=args.steps)
    print(f"done: {report.steps_run} steps, loss "
          f"{report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
