"""Serving launcher: continuous batching over the hybrid KV pool.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \\
        --requests 8 --max-new 16 [--mode hybrid|flexible_only|restrictive_only] \\
        [--prefill-budget 128] [--scheduler fifo|spf|priority] \\
        [--temperature 0.8 --top-k 40 --top-p 0.95 --seed 0] \\
        [--spec-decode --num-draft-tokens 4] [--data 1 --model 2] \\
        [--shared-prefix-blocks 4] [--no-prefix-cache] \\
        [--metrics[=PATH] --metrics-every 10]

Drives the request-centric engine API: requests are submitted up front
with per-request SamplingParams, the configured Scheduler admits them
under the per-step prefill token budget (chunking prompts longer than
the budget), finished sequences auto-release so their slots recycle,
and generation is consumed as a stream of RequestOutput snapshots.  The
run prints throughput plus translation statistics — global (RSW hit
rate, migrations, swaps) and attributed per request.

``--metrics`` attaches a live ``MetricsLogger`` (serve/metrics.py) and
prints a one-line rolling dashboard — tokens/s, step p50/p99, pool
occupancy, RestSeg hit rate, spec acceptance, prefix-cache hit rate,
preempt/resume — every ``--metrics-every`` steps; ``--metrics=PATH``
additionally streams every per-step event to a JSONL file.  All run and
per-request latencies come from the logger's monotonic clock
(``time.perf_counter`` — wall-clock ``time.time`` is NTP-step-prone),
so the dashboard and the printout cannot disagree.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import model_dims, init_params
from repro.serve import (Engine, EngineConfig, JsonlSink, MetricsLogger,
                         Request, SamplingParams)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-blocks", type=int, default=2)
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prompt tokens admitted per engine step "
                         "(default: 4 * block_size * max_batch)")
    ap.add_argument("--mode", default="hybrid",
                    choices=["hybrid", "flexible_only", "restrictive_only"])
    ap.add_argument("--scheduler", default="fifo",
                    choices=["fifo", "spf", "priority"])
    ap.add_argument("--prefill-mode", default="prefix_kv",
                    choices=["prefix_kv", "recompute"],
                    help="chunk k>0 path: prefix-KV pool read (linear "
                         "chunk cost) or full-prefix recompute (oracle)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (the fast path)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="base sampling seed; request sid uses seed + sid "
                         "(default: per-request seq_id)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: self-drafted n-gram "
                         "drafts verified in-graph, K tokens per "
                         "dispatch (lossless — streams are identical to "
                         "spec-off; recurrent families fall back)")
    ap.add_argument("--num-draft-tokens", type=int, default=4,
                    help="draft window width K (with --spec-decode)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the automatic content-addressed prefix "
                         "cache (on by default: identical prompt prefixes "
                         "dedupe to shared pool blocks)")
    ap.add_argument("--shared-prefix-blocks", type=int, default=0,
                    help="prepend this many IDENTICAL prompt blocks to "
                         "every request (a shared system prompt) — the "
                         "workload the prefix cache dedupes")
    ap.add_argument("--data", type=int, default=1,
                    help="mesh data-axis size (replicated engine state)")
    ap.add_argument("--model", type=int, default=1,
                    help="mesh model-axis size: shards the KV pool and "
                         "TAR/SF/flex tables (DESIGN.md §sharded-serving)."
                         " On CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--metrics", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="attach the live MetricsLogger and print a "
                         "rolling one-line dashboard; with a PATH, also "
                         "stream per-step events to a JSONL file")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="dashboard print interval in engine steps "
                         "(with --metrics)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="crash-safe serving: wrap the engine in "
                         "ResilientServe and snapshot every N steps "
                         "(0 = off; DESIGN.md §crash-recovery)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist snapshots through ckpt."
                         "CheckpointManager under this directory "
                         "(implies --snapshot-every 10 if unset)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="bounded restart budget before the supervisor "
                         "re-raises the fault (with --snapshot-every)")
    ap.add_argument("--crash-at", default=None, metavar="STEPS",
                    help="kill-and-recover demo: inject an "
                         "InjectedStepFault at these engine steps "
                         "(comma list) — with --snapshot-every the "
                         "supervisor restores and replays; streams are "
                         "bit-identical to an uncrashed run")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    if args.snapshot_dir is not None and args.snapshot_every == 0:
        args.snapshot_every = 10

    # the logger is always attached (it is host-side arithmetic only and
    # provably stream-invisible); --metrics controls what gets SHOWN
    sinks = [JsonlSink(args.metrics)] if args.metrics else []
    logger = MetricsLogger(sinks)
    show_metrics = args.metrics is not None

    cfg = reduce_cfg(get_config(args.arch)) if args.reduced \
        else get_config(args.arch)
    dims = model_dims(cfg, tp=1)
    params = init_params(jax.random.PRNGKey(0), cfg, dims)
    bs = cfg.kv_block_size
    S = (args.prompt_blocks + args.shared_prefix_blocks) * bs
    # no speculative headroom: a verify window overrunning the last KV
    # block is re-verified, not committed, so spec-on and spec-off run
    # the same pool sizing (stats stay apples-to-apples)
    injector = None
    if args.crash_at:
        from repro.runtime import ServeFaultInjector
        injector = ServeFaultInjector(crash_at=[
            (int(s), "pre") for s in args.crash_at.split(",")])
    eng = Engine(cfg, params, EngineConfig(
        max_batch=args.max_batch,
        max_seq_len=S + cfg.frontend_tokens + args.max_new + bs,
        # a shared-prefix demo needs a bounded admission budget: with
        # room for every prompt in round 1, followers admit before
        # request 0's blocks are published (insertion is post-dispatch)
        # and the cache never gets a chance to hit
        mode=args.mode, prefill_budget=(
            S if args.prefill_budget is None
            and args.shared_prefix_blocks > 0 else args.prefill_budget),
        auto_release=True, scheduler=args.scheduler,
        prefill_mode=args.prefill_mode,
        spec_decode="ngram" if args.spec_decode else None,
        num_draft_tokens=args.num_draft_tokens,
        prefix_cache=False if args.no_prefix_cache else "auto",
        metrics=logger,
        fault_injector=injector,
        mesh_shape=((args.data, args.model)
                    if (args.data, args.model) != (1, 1) else None)))
    sup = None
    if args.snapshot_every > 0:
        from repro.runtime import ResilientServe
        ckpt_mgr = None
        if args.snapshot_dir is not None:
            from repro.ckpt import CheckpointManager
            ckpt_mgr = CheckpointManager(args.snapshot_dir)
        sup = ResilientServe(eng, ckpt_mgr,
                             snapshot_every=args.snapshot_every,
                             max_restarts=args.max_restarts)
    drv = sup if sup is not None else eng

    def sampling(sid):
        # distinct per-request PRNG streams: one shared seed would make
        # identical prompts produce identical "sampled" token streams
        return SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p,
            seed=None if args.seed is None else args.seed + sid)

    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab_size,
                         args.shared_prefix_blocks * bs)
    # monotonic clock: wall-clock time.time() measures an NTP step as
    # request latency (the ISSUE 9 bugfix) — the MetricsLogger uses
    # perf_counter too, so the dashboard and this printout agree
    t0 = time.perf_counter()
    for sid in range(args.requests):
        frontend = (rng.randn(cfg.frontend_tokens, cfg.d_model)
                    .astype(np.float32) if cfg.frontend != "none" else None)
        prompt = np.concatenate([
            shared, rng.randint(0, cfg.vocab_size,
                                args.prompt_blocks * bs)])
        drv.submit(Request(
            seq_id=sid, prompt=prompt,
            frontend=frontend, max_new_tokens=args.max_new,
            sampling=sampling(sid), priority=sid % 3))
    tokens = 0
    shown_at = 0
    while drv.has_unfinished():
        for out in drv.poll():
            tokens += len(out.new_token_ids)
        if (show_metrics
                and eng.step_count - shown_at >= args.metrics_every):
            print(logger.dashboard_line(), flush=True)
            shown_at = eng.step_count
    if show_metrics and eng.step_count != shown_at:
        print(logger.dashboard_line(), flush=True)
    logger.close()
    dt = time.perf_counter() - t0
    steps = eng.step_count
    spec_note = (f", spec K={args.num_draft_tokens}" if eng.spec_K
                 else "")
    if eng.mesh is not None:
        spec_note += f", mesh=(data={args.data}, model={args.model})"
    print(f"arch={cfg.name} mode={args.mode} sched={args.scheduler}: "
          f"{args.requests} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s, {steps} engine steps, "
          f"budget={eng.prefill_budget} tok/step, "
          f"temp={args.temperature}{spec_note})")
    st = drv.stats()
    life = st.get("lifecycle", {})
    if sup is not None:
        rec = st["recovery"]
        print(f"recovery: restarts={rec['restarts']}/"
              f"{rec['max_restarts']} snapshots={rec['snapshots']} "
              f"(every {rec['snapshot_every']} steps, last at step "
              f"{rec['last_snapshot_step']}) "
              f"replayed_steps={rec['replayed_steps']} "
              f"dedup_tokens={rec['dedup_tokens']} "
              f"persisted={rec['persisted']} "
              f"cancelled={life.get('cancelled', 0)} "
              f"deadline_expired={life.get('deadline_expired', 0)}")
        if sup.ckpt is not None:
            sup.ckpt.wait()
    total = st.get("rsw_hits", 0) + st.get("flex_walks", 0)
    print(f"translation: rsw_hit_rate="
          f"{st.get('rsw_hits', 0) / max(total, 1):.2%} "
          f"migrations={st.get('migrations_rest_to_flex', 0) + st.get('migrations_flex_to_rest', 0)} "
          f"swaps={st.get('swap_out', 0)}")
    pcs = st["prefix_cache"]
    print(f"prefix cache: enabled={pcs['enabled']} "
          f"lookups={pcs['lookups']} hits={pcs['hits']} "
          f"dedup_blocks={pcs['dedup_blocks']} "
          f"bytes_saved={pcs['bytes_saved'] / 2**10:.0f}KiB "
          f"evictions={pcs['evictions']} "
          f"resident_entries={pcs['cached_blocks']}")
    if eng.spec_K:
        print(f"speculation: drafted={st['spec_drafted']} "
              f"accepted={st['spec_accepted']} "
              f"(acceptance "
              f"{st['spec_accepted'] / max(st['spec_drafted'], 1):.2%})")
    for sid, row in sorted(st["per_request"].items()):
        seen = row["rsw_hits"] + row["flex_walks"]
        spec_row = ""
        if eng.spec_K:
            spec_row = (f" accepted={row['accepted']}/{row['drafted']}"
                        f" ({row['accepted'] / max(row['drafted'], 1):.0%})")
        # submit-to-finish latency, from the logger's monotonic clock —
        # the single source the dashboard reads too
        lat = logger.request_latencies.get(sid)
        lat_row = f" latency={lat * 1e3:.0f}ms" if lat is not None else ""
        print(f"  seq {sid}: rsw_hits={row['rsw_hits']}/{seen} "
              f"flex_walks={row['flex_walks']} "
              f"swap_faults={row['swap_faults']} "
              f"cached_blocks={row['cached_blocks']}{lat_row}{spec_row}")


if __name__ == "__main__":
    main()
