"""Named perf variants for the §Perf hillclimb.

A variant is a (cfg, rules, fwd-overrides) transform applied before a
dry-run cell is built, so each hypothesis in EXPERIMENTS.md §Perf is a
one-flag re-run:  ``--perf-variant triangular`` etc.
"""
from __future__ import annotations

import dataclasses


def apply(name: str, cfg, rules, shape, multi_pod: bool):
    """Returns (cfg, rules, fwd_overrides dict)."""
    fwd = {}
    if name == "baseline":
        return cfg, rules, fwd
    if name == "triangular":
        # causal flash: skip above-diagonal KV chunks (halves attn FLOPs)
        fwd["triangular_schedule"] = True
        return cfg, rules, fwd
    if name == "no_zero":
        # keep params TP-only (no data-axis FSDP): removes per-layer
        # all-gathers at the cost of replicated param memory
        rules = dataclasses.replace(rules, zero_params=False)
        return cfg, rules, fwd
    if name == "no_remat":
        cfg = dataclasses.replace(cfg, remat=False)
        return cfg, rules, fwd
    if name == "remat":
        cfg = dataclasses.replace(cfg, remat=True)
        return cfg, rules, fwd
    if name == "big_chunks":
        fwd["q_chunk"] = 2048
        fwd["kv_chunk"] = 2048
        return cfg, rules, fwd
    if name == "small_chunks":
        fwd["q_chunk"] = 512
        fwd["kv_chunk"] = 512
        return cfg, rules, fwd
    if name == "triangular_no_zero":
        fwd["triangular_schedule"] = True
        rules = dataclasses.replace(rules, zero_params=False)
        return cfg, rules, fwd
    if name == "gather_once":
        rules = dataclasses.replace(rules, gather_once=True)
        return cfg, rules, fwd
    if name == "gather_once_no_zero":
        rules = dataclasses.replace(rules, gather_once=True,
                                    zero_params=False)
        return cfg, rules, fwd
    if name == "gather_once_triangular":
        rules = dataclasses.replace(rules, gather_once=True)
        fwd["triangular_schedule"] = True
        return cfg, rules, fwd
    if name == "kv_int8":
        return cfg, rules, {"_kv_int8": True}
    if name == "kv_int8_no_zero":
        rules = dataclasses.replace(rules, zero_params=False)
        return cfg, rules, {"_kv_int8": True}
    if name == "megatron":
        return cfg, rules, {"_megatron": True}
    if name == "megatron_triangular":
        fwd["triangular_schedule"] = True
        fwd["_megatron"] = True
        return cfg, rules, fwd
    if name == "nosp_mb8":
        cfg = dataclasses.replace(cfg, train_microbatches=8)
        rules = dataclasses.replace(rules, shard_activations=False)
        return cfg, rules, fwd
    if name == "nosp_mb8_triangular":
        cfg = dataclasses.replace(cfg, train_microbatches=8)
        rules = dataclasses.replace(rules, shard_activations=False)
        fwd["triangular_schedule"] = True
        return cfg, rules, fwd
    if name == "nosp_mb16":
        cfg = dataclasses.replace(cfg, train_microbatches=16)
        rules = dataclasses.replace(rules, shard_activations=False)
        return cfg, rules, fwd
    if name == "mb1":
        cfg = dataclasses.replace(cfg, train_microbatches=1)
        return cfg, rules, fwd
    if name == "mb2":
        cfg = dataclasses.replace(cfg, train_microbatches=2)
        return cfg, rules, fwd
    if name == "mb1_triangular":
        cfg = dataclasses.replace(cfg, train_microbatches=1)
        fwd["triangular_schedule"] = True
        return cfg, rules, fwd
    if name == "mb8":
        cfg = dataclasses.replace(cfg, train_microbatches=8)
        return cfg, rules, fwd
    if name == "mb16":
        cfg = dataclasses.replace(cfg, train_microbatches=16)
        return cfg, rules, fwd
    if name == "capacity_1":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=1.0)
        return cfg, rules, fwd
    if name == "capacity_2":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=2.0)
        return cfg, rules, fwd
    raise ValueError(f"unknown perf variant {name!r}")
