"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import to obtain the placeholder devices (see launch/dryrun.py).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests).

    Raises a clear error when the requested shape exceeds the local
    device count instead of letting ``jax.make_mesh`` fail obscurely.
    """
    need, have = data * model, jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape ({data}, {model}) needs {need} devices but only "
            f"{have} are visible; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            "importing jax (N >= data * model)")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def data_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
