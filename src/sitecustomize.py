"""Auto-installed compatibility shims (see repro/compat.py).

Python imports the FIRST ``sitecustomize`` on ``sys.path`` at interpreter
startup; every entry point in this repo runs with ``PYTHONPATH=src``, so
this file transparently upgrades older jax installs to the API surface
the code expects — including for test subprocesses that import
``jax.sharding.AxisType`` before any ``repro`` module (which a
package-__init__ hook could not reach).

Trade-off, recorded deliberately: with ``src`` on the path this shadows
any venv/distro sitecustomize (none ships in this repo's container), and
it imports jax in every process inheriting the path.  ``XLA_FLAGS`` is
still honored because XLA reads it lazily at backend init, not at import
(verified; see repro/compat.py).

Only ImportError (jax absent) is swallowed; a genuine shim failure must
surface here, not as a confusing late AttributeError.
"""
try:
    import jax  # noqa: F401  (absent jax = nothing to shim)
    from repro import compat as _compat
except ImportError:  # pragma: no cover - jax (or repro) not importable
    pass
else:
    _compat.install()
